"""The out-of-process worker loop (DESIGN.md §13).

One worker owns one protocol slot ``n``.  It receives its plan
parameters over the wire, resolves the SAME data-independent tables the
dealer uses — :func:`repro.mpc.planner.get_plan` is deterministic
(invertibility-searched α's with fixed re-seeding), so a worker process
rebuilds bit-identical Vandermonde/G-mix tables without ever shipping
them — and then serves blocks until the socket closes:

* ``shares``  → run the plan's staged jit ``worker_compute`` program on
  its ``[1, …]`` share slice (phase 2 compute) and reply with its G-mix
  contribution ``g_n[n'] = c_{n,n'} · H(α_n) mod p`` for every receiver
  ``n'`` (phase-2 exchange, upstream half);
* ``ipoint``  → store this slot's aggregated ``I(α_n)`` and echo it back
  (phase-3 download) — the echo is what makes a late/dead worker a
  *phase-3* loss the survivor mask absorbs for free;
* ``chaos``   → test-only fault hooks (die/stall at a scripted block),
  driving the same schedules ``byzantine.FaultInjector`` serializes;
* ``stop``    → exit the loop.

Replies are cached per block id, so a dealer retry (duplicate request
after a deadline) is answered idempotently from the cache instead of
recomputing — exactly-once effects over at-least-once delivery.
"""
from __future__ import annotations

import socket
import time
from typing import Dict, Optional, Tuple

import numpy as np

from .framing import WIRE_VERSION, TransportClosed, recv_msg, send_msg

#: per-worker reply cache depth (blocks); must cover the dealer's largest
#: in-flight window plus retry skew
REPLY_CACHE = 8


def _build_state(doc: Dict):
    """Resolve (spec, plan, stages, slot) from a ``plan`` message."""
    from ..mpc.api import MPCSpec
    from ..mpc.field import Field

    if doc.get("wire") != WIRE_VERSION:
        raise TransportClosed(
            f"wire version {doc.get('wire')!r} != {WIRE_VERSION}")
    spec = MPCSpec(
        s=int(doc["s"]), t=int(doc["t"]), z=int(doc["z"]),
        lam=None if doc["lam"] is None else int(doc["lam"]),
        scheme=str(doc["scheme"]),
        field=Field(p=int(doc["p"]), frac_bits=int(doc["frac_bits"])),
        m=int(doc["m"]))
    plan = spec.plan()
    return spec, plan, plan.stages(), int(doc["device"])


class _Chaos:
    """Scripted fault hooks for one worker (test-only).

    ``die_block``/``die_after``: close the connection while serving that
    block — ``after="shares"`` is a phase-2 loss (no G contribution ever
    leaves), ``after="ipoint"`` a phase-3 loss (the I point exists but
    the download dies).  ``stall_block``/``stall_s``: sleep before
    replying, long enough to trip the dealer's deadline.
    """

    def __init__(self):
        self.die_block: Optional[int] = None
        self.die_after = "shares"
        self.stall_block: Optional[int] = None
        self.stall_s = 0.0

    def update(self, doc: Dict) -> None:
        if "die_block" in doc:
            self.die_block = (None if doc["die_block"] is None
                              else int(doc["die_block"]))
            self.die_after = str(doc.get("die_after", "shares"))
        if "stall_block" in doc:
            self.stall_block = (None if doc["stall_block"] is None
                                else int(doc["stall_block"]))
            self.stall_s = float(doc.get("stall_s", 0.0))

    def maybe_stall(self, bid: int) -> None:
        if self.stall_block is not None and bid == self.stall_block:
            time.sleep(self.stall_s)

    def dies_at(self, bid: int, point: str) -> bool:
        return self.die_block is not None and bid == self.die_block \
            and self.die_after == point


def worker_main(sock: socket.socket) -> None:
    """Serve one worker slot over ``sock`` until EOF/``stop``.

    Runs as a thread target (loopback tests: ``spawn="thread"``) or as
    the body of a spawned process (:func:`process_worker`).  All compute
    goes through the plan's staged jit programs — the same compiled
    stages the in-process backends dispatch.
    """
    plan = stages = None
    slot = -1
    g_row = None
    p = 0
    chaos = _Chaos()
    cache: Dict[Tuple[int, str], Tuple[Dict, Dict]] = {}
    try:
        while True:
            meta, arrays = recv_msg(sock, timeout=None)
            kind = meta.get("kind")
            if kind == "stop":
                return
            if kind == "chaos":
                chaos.update(meta)
                continue
            if kind == "plan":
                _, plan, stages, slot = _build_state(meta)
                p = plan.p
                # this slot's G-mix scalars c_{n, n'} for every receiver
                g_row = plan.g_mix[slot].astype(np.int64)
                cache.clear()
                send_msg(sock, {"kind": "ready", "device": slot,
                                "wire": WIRE_VERSION})
                continue
            bid = int(meta["block"])
            cached = cache.get((bid, kind))
            if cached is not None:  # dealer retry: answer idempotently
                cached[0]["mono"] = time.monotonic()
                send_msg(sock, *cached)
                continue
            chaos.maybe_stall(bid)
            if kind == "shares":
                t0 = time.perf_counter()
                h = stages.worker_compute(arrays["f_a"][None],
                                          arrays["f_b"][None])[0]
                # g_n[n', :] = c_{n,n'} · vec(H(α_n)) mod p — both factors
                # < p, so the product fits int64 exactly for any p < 2³¹·⁵
                # analysis: allow(host-sync): wire boundary, reply needs host bytes
                h_flat = np.asarray(h, np.int64).reshape(1, -1)
                g = (g_row[:, None] * h_flat) % p
                us = (time.perf_counter() - t0) * 1e6
                if chaos.dies_at(bid, "shares"):
                    return
                reply = ({"kind": "gvec", "block": bid, "device": slot,
                          "compute_us": us}, {"g": g})
            elif kind == "ipoint":
                if chaos.dies_at(bid, "ipoint"):
                    return
                reply = ({"kind": "result", "block": bid, "device": slot},
                         {"i": arrays["i"]})
            else:
                raise TransportClosed(f"unknown frame kind {kind!r}")
            cache[(bid, reply[0]["kind"])] = reply
            while len(cache) > REPLY_CACHE:
                cache.pop(next(iter(cache)))
            # send stamp for the dealer's simulated-latency delivery
            # (CLOCK_MONOTONIC is system-wide, so process mode works too)
            reply[0]["mono"] = time.monotonic()
            send_msg(sock, *reply)
    except (TransportClosed, OSError):
        return  # dealer hung up / killed the link: a clean worker death
    finally:
        try:
            sock.close()
        except OSError:
            pass


def process_worker(host: str, port: int, device: int) -> None:
    """Entry point for ``spawn="process"`` workers.

    Top-level so the multiprocessing ``spawn`` start method can pickle
    it; connects back to the dealer's listener and identifies its slot
    with a ``hello`` frame before entering :func:`worker_main`.
    """
    sock = socket.create_connection((host, port), timeout=60.0)
    send_msg(sock, {"kind": "hello", "device": int(device),
                    "wire": WIRE_VERSION})
    sock.settimeout(None)
    worker_main(sock)

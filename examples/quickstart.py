"""Quickstart: AGE-CMPC in 40 lines.

Two sources hold private matrices A and B; N workers jointly compute
their product without any z-subset of them learning anything about A or B.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import all_worker_counts, optimal_age_code  # noqa: E402
from repro.mpc import MPCSpec, connect  # noqa: E402

# 1. Plan: how many edge workers does each scheme need? (paper Fig. 2 cell)
s, t, z = 2, 2, 2
print("worker counts:", all_worker_counts(s, t, z))
code, lam = optimal_age_code(s, t, z)
print(f"AGE picks gap λ*={lam}: N={code.n_workers}, "
      f"decode threshold t²+z={code.recovery_threshold}")

# 2. One spec, one session, floats in / floats out — any shapes.
spec = MPCSpec(s=s, t=t, z=z)
sess = connect(spec)                       # backend="local" | "sharded" | "batched"
rng = np.random.default_rng(0)
a = rng.standard_normal((16, 16))
b = rng.standard_normal((16, 16))
y = np.asarray(sess.matmul(a, b))
print("max |Y - AB| =", float(np.abs(y - a @ b).max()))

# ... including rectangular: the square protocol is tiled underneath.
yr = np.asarray(sess.matmul(rng.standard_normal((3, 20)),
                            rng.standard_normal((20, 5))))
print("rectangular [3,20]x[20,5] ->", yr.shape)

# 3. Coded fault tolerance: kill workers down to the threshold, same answer.
surv = np.zeros(spec.n_workers, bool)
surv[np.arange(spec.recovery_threshold)] = True
y2 = np.asarray(sess.matmul(a, b, survivors=surv))
print(f"decode from only {spec.recovery_threshold}/{spec.n_workers} "
      f"workers: max err {float(np.abs(y2 - a @ b).max()):.4f}")

# 4. Legacy surface (kept as thin shims over the session): the protocol
#    object computes AᵀB on square field-encoded blocks.
from repro.mpc import AGECMPCProtocol  # noqa: E402

proto = AGECMPCProtocol.from_spec(spec, m=16)
f = proto.field
y3 = proto.run(f.encode(a), f.encode(b), jax.random.PRNGKey(0))
y3 = np.asarray(f.decode(y3, products=2))
print("legacy protocol.run (Y = AᵀB): max |Y - AᵀB| =",
      float(np.abs(y3 - a.T @ b).max()))

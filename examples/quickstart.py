"""Quickstart: AGE-CMPC in 40 lines.

Two sources hold private matrices A and B; N workers jointly compute
Y = AᵀB without any z-subset of them learning anything about A or B.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import all_worker_counts, optimal_age_code  # noqa: E402
from repro.mpc import AGECMPCProtocol  # noqa: E402

# 1. Plan: how many edge workers does each scheme need? (paper Fig. 2 cell)
s, t, z = 2, 2, 2
print("worker counts:", all_worker_counts(s, t, z))
code, lam = optimal_age_code(s, t, z)
print(f"AGE picks gap λ*={lam}: N={code.n_workers}, "
      f"decode threshold t²+z={code.recovery_threshold}")

# 2. Execute the 3-phase protocol on real data.
m = 16
proto = AGECMPCProtocol(s=s, t=t, z=z, m=m)
rng = np.random.default_rng(0)
a = rng.standard_normal((m, m))
b = rng.standard_normal((m, m))
f = proto.field
y = proto.run(f.encode(a), f.encode(b), jax.random.PRNGKey(0))
y = np.asarray(f.decode(y, products=2))
print("max |Y - AᵀB| =", float(np.abs(y - a.T @ b).max()))

# 3. Coded fault tolerance: kill workers down to the threshold, same answer.
surv = np.zeros(proto.n_workers, bool)
surv[np.arange(proto.recovery_threshold)] = True
y2 = proto.run(f.encode(a), f.encode(b), jax.random.PRNGKey(1),
               survivors=surv)
y2 = np.asarray(f.decode(y2, products=2))
print(f"decode from only {proto.recovery_threshold}/{proto.n_workers} "
      f"workers: max err {float(np.abs(y2 - a.T @ b).max()):.4f}")

"""End-to-end training driver: train a small llama-family model for a few
hundred steps on synthetic data with WSD schedule + async checkpointing,
then kill/restart to prove exact resume.

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse
import shutil
import sys
import tempfile

sys.path.insert(0, "src")

from repro.configs import get_config, reduced  # noqa: E402
from repro.launch.train import train_loop  # noqa: E402
from repro.train.step import TrainConfig  # noqa: E402

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=60)
args = ap.parse_args()

cfg = reduced(get_config("llama3.2-1b"))
tc = TrainConfig(peak_lr=1e-3, warmup=5, stable=args.steps, decay=10,
                 seq_chunk=32)
ckpt = tempfile.mkdtemp(prefix="age_ckpt_")
try:
    # phase 1: train halfway
    half = args.steps // 2
    _, _, losses1 = train_loop(cfg, tc, steps=half, global_batch=8,
                               seq_len=64, ckpt_dir=ckpt, ckpt_every=10)
    # phase 2: "restart" — a fresh loop resumes from the checkpoint
    _, _, losses2 = train_loop(cfg, tc, steps=args.steps, global_batch=8,
                               seq_len=64, ckpt_dir=ckpt, ckpt_every=10)
    print(f"loss: start {losses1[0]:.3f} -> mid {losses1[-1]:.3f} "
          f"-> end {losses2[-1]:.3f}")
    assert losses2[-1] < losses1[0], "loss should decrease over training"
    print("train + checkpoint/restart OK")
finally:
    shutil.rmtree(ckpt, ignore_errors=True)

"""Elastic worker-pool demo: spares, phase-2 failures, re-planning, and
batched serving with per-request dropout — all through the unified
session API (``repro.mpc.connect``).

    PYTHONPATH=src python examples/elastic_mpc.py
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.mpc import MPCSpec, connect  # noqa: E402
from repro.mpc.elastic import ElasticPool  # noqa: E402

spec = MPCSpec(s=2, t=2, z=2, m=8)
pool = ElasticPool.from_spec(spec, spares=3)
n = spec.n_workers
print(f"plan: N={n} workers + {pool.spares} spares; "
      f"phase-3 tolerance {pool.phase3_tolerance()} failures")
print(f"pool alphas extend the plan's invertible set: "
      f"{pool._alphas[:n].tolist()} + spares {pool._alphas[n:].tolist()}")

# lose two workers BEFORE the exchange: spares absorb them, and the quorum
# weights come out of the plan's survivor-solve LRU
pool.fail([0, 7])
idx, _ = pool.reconstruction_weights()
print(f"after 2 failures: quorum from workers {idx[:5].tolist()}... "
      f"(spares activated: {sorted(set(idx) - set(range(n)))}); "
      f"solve cache {pool.proto.plan.solve_cache_info()}")

# ---- batched serving with heterogeneous per-request dropout -------------
sess = connect(spec, backend="batched", spares=3, max_batch=16)
rng = np.random.default_rng(0)
p = spec.field.p
expected = {}
for i in range(8):
    a = rng.integers(0, p, (8, 8))
    b = rng.integers(0, p, (8, 8))
    surv = None
    if i % 2:  # every other request loses a random straggler set
        surv = np.ones(n, bool)
        surv[rng.choice(n, pool.phase3_tolerance(), replace=False)] = False
    rid = sess.submit(a, b, key=jax.random.PRNGKey(i), survivors=surv,
                      encoded=True)
    expected[rid] = np.array(
        (a.astype(object) @ b.astype(object)) % p, np.int64)
results = sess.flush()
ok = all(np.array_equal(np.asarray(results[r]), expected[r])
         for r in expected)
print(f"session: 8 mixed-dropout requests -> {len(results)} correct={ok}; "
      f"engine stats {sess.backend.engine.stats}")

# catastrophic loss: below N -> the backend escalates to a coarser plan
sess.fail(list(range(1, 14)))
a = rng.integers(0, p, (8, 8))
b = rng.integers(0, p, (8, 8))
y = sess.matmul(a, b, key=jax.random.PRNGKey(42), encoded=True)
ok = np.array_equal(
    np.asarray(y), np.array((a.astype(object) @ b.astype(object)) % p,
                            np.int64))
print(f"after losing 13 workers: replanned and served correct={ok}; "
      f"engine stats {sess.backend.engine.stats}")

"""Elastic worker-pool demo: spares, phase-2 failures, re-planning.

    PYTHONPATH=src python examples/elastic_mpc.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.mpc.elastic import ElasticPool  # noqa: E402

pool = ElasticPool(s=2, t=2, z=2, m=8, spares=3)
print(f"plan: N={pool.proto.n_workers} workers + {pool.spares} spares; "
      f"phase-3 tolerance {pool.phase3_tolerance()} failures")

# lose two workers BEFORE the exchange: spares absorb them
pool.fail([0, 7])
idx, _ = pool.reconstruction_weights()
print(f"after 2 failures: quorum from workers {idx[:5].tolist()}... "
      f"(spares activated: {sorted(set(idx) - set(range(17)))})")

# catastrophic loss: below N -> re-plan with coarser partitioning
pool.fail(list(range(1, 12)))
try:
    pool.active_subset()
except RuntimeError as e:
    print("pool infeasible:", e)
new = pool.replan()
print(f"re-planned: (s={new.s}, t={new.t}) needs N={new.n_workers} "
      f"<= {int(pool.alive.sum())} alive")

"""Fleet simulator demo (DESIGN.md §11): tune over a 1000-device skewed
fleet, replay tuned vs capacity-oblivious placement through the
discrete-event simulator, survive an attrition + Byzantine schedule, and
close the calibration loop — fit per-class (ξ, σ, ζ) multipliers from
the replay's own phase trace and watch the recalibrated model predict
the fleet it measured.

    PYTHONPATH=src python examples/fleet_sim_demo.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.mpc.autotune import CostModel, predicted_makespan, tune  # noqa: E402
from repro.sim import (  # noqa: E402
    ArrivalTrace,
    FleetEvent,
    FleetModel,
    calibrate,
    predict,
    replay,
)
from repro.sim.divergence import gate, skewed_fleet_pool  # noqa: E402

# ---- 1. a 1000-device fleet: 960 phones + 40 gateways -------------------
pool = skewed_fleet_pool(1000)
print(f"fleet: {pool.describe()} ({len(pool)} devices)")
cost = CostModel.from_bench("BENCH_PROTOCOL.json")
res = tune(pool=pool, z=2, shape=(96, 96, 96), cost=cost)
spec = res.spec
print(f"tuned: {spec.scheme} s={spec.s} t={spec.t} N={spec.n_workers} "
      f"m={spec.m}; placement classes: "
      f"{sorted({pool[d].name for d in spec.placement})}")

# ---- 2. replay tuned vs capacity-oblivious at fleet scale ---------------
# a closed burst (all requests at t=0) keeps the fleet saturated, so the
# makespan gap IS the placement gap; an open poisson trace (leg 3) is
# arrival-limited and measures fault behavior instead
trace = ArrivalTrace.burst(64)
oblivious = dataclasses.replace(spec,
                                placement=tuple(range(spec.n_workers)))
reports = {}
for label, sp in (("tuned", spec), ("oblivious", oblivious)):
    fleet = FleetModel(pool, jitter=0.03, seed=3)
    reports[label] = replay(sp, trace, cost=cost, fleet=fleet)
tuned, obl = reports["tuned"], reports["oblivious"]
print(f"replayed makespan: tuned {tuned.makespan_us:.3e}µs vs oblivious "
      f"{obl.makespan_us:.3e}µs ({obl.makespan_us / tuned.makespan_us:.1f}x "
      f"win, {tuned.waves} waves for {len(trace)} requests)")
assert tuned.makespan_us < obl.makespan_us, \
    "replay must reproduce the cost model's placement ranking"
pred = predict(spec, trace, cost=cost)
print(f"predicted {pred.makespan_us:.3e}µs -> replayed/predicted ratio "
      f"{tuned.makespan_us / pred.makespan_us:.3f}")

# ---- 3. attrition + Byzantine schedule over an open arrival trace ------
open_trace = ArrivalTrace.poisson(64, rate_rps=40.0, seed=7)
quorum = spec.placement[: spec.t * spec.t + spec.z]
faulty = open_trace.with_faults(
    FleetEvent(at_us=0.0, device=int(quorum[0]), kind="fail"),
    FleetEvent(at_us=0.0, device=int(quorum[1]), kind="corrupt"))
byz_spec = dataclasses.replace(spec, adversaries=1)
fleet = FleetModel(pool, jitter=0.03, seed=3)
rep = replay(byz_spec, faulty, cost=cost, fleet=fleet)
print(f"under faults: served {rep.served}/{len(trace)}, "
      f"replans={rep.replans}, corrections={rep.corrections}, "
      f"evictions={rep.evictions}")
assert rep.served == len(trace) and rep.evictions >= 1

# ---- 4. close the loop: calibrate from the replay's own trace ----------
planted = {"phone": (1.8, 1.4, 2.2)}
drifted = FleetModel(pool, class_multipliers=planted, jitter=0.02, seed=5)
measured = replay(oblivious, trace, cost=cost, fleet=drifted)
cal = calibrate(measured.samples, pool, cost)
got = cal.multipliers["phone"]
print(f"planted phone multipliers {planted['phone']} -> recovered "
      f"({got[0]:.2f}, {got[1]:.2f}, {got[2]:.2f}) "
      f"from {cal.samples_used} phase samples")
assert all(abs(g - p) / p < 0.15 for g, p in zip(got, planted["phone"], strict=True))
before = predicted_makespan(oblivious, cost=cost)
after = predicted_makespan(oblivious, cost=cal.cost)
print(f"recalibrated model: oblivious block makespan {before:.3e} -> "
      f"{after:.3e}µs (now tracks the measured fleet)")

# ---- 5. the CI gate, end to end ----------------------------------------
report = gate(seed=0)
assert report.ok, f"divergence gate failed: {report.describe()}"
print("divergence gate OK: "
      + ", ".join(f"{e.label} ratio {e.ratio:.3f}" for e in report.entries))
print("fleet sim demo OK")

"""Private inference: route a model's linear layer through AGE-CMPC.

A tiny LM computes its lm_head projection under MPC — the activations
(one party) and the weights (another party) stay private from the worker
pool; only the logits emerge.

    PYTHONPATH=src python examples/private_inference.py
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.models import transformer as tr  # noqa: E402
from repro.mpc.secure_matmul import secure_matmul  # noqa: E402

cfg = reduced(get_config("llama3.2-1b"))
params = tr.init_params(cfg, jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)

hidden, _ = tr.forward(cfg, params, toks)
h_last = np.asarray(hidden[0, -1:], np.float32)           # [1, D]

# head weights: [D, V] (tied embeddings -> embed.T)
head = np.asarray(params.get("lm_head", params["embed"].T), np.float32)

# plaintext logits
logits_plain = h_last @ head

# MPC logits: Y = AᵀB with A = h_lastᵀ (source 1), B = head (source 2).
d = cfg.d_model
a = np.zeros((d, d), np.float32)
a[:, 0] = h_last[0]
cols = min(d, head.shape[1])
b = head[:, :cols]
bb = np.zeros((d, d), np.float32)
bb[:, :cols] = b
y = secure_matmul(a, bb, s=2, t=2, z=2)                   # [d, d]
logits_mpc = np.asarray(y)[0, :cols]

err = np.abs(logits_mpc - logits_plain[0, :cols]).max()
print(f"first {cols} logits via AGE-CMPC: max |Δ| = {err:.4f}")
assert err < 0.1
print("private inference OK — workers saw only secret shares")

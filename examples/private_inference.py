"""Private inference: route a model's linear layer through AGE-CMPC.

A tiny LM computes its lm_head projection under MPC — the activations
(one party) and the weights (another party) stay private from the worker
pool; only the logits emerge.

The projection is the real serving shape: a rectangular ``[1, D] × [D, V]``
matmul over the FULL vocabulary.  The session's shape adapter tiles it onto
the coded ``m×m`` block grid (zero-padding is exact in the field), so no
square-embedding or vocab-truncation tricks are needed.

    PYTHONPATH=src python examples/private_inference.py
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.models import transformer as tr  # noqa: E402
from repro.mpc import MPCSpec, connect  # noqa: E402

cfg = reduced(get_config("llama3.2-1b"))
params = tr.init_params(cfg, jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)

hidden, _ = tr.forward(cfg, params, toks)
h_last = np.asarray(hidden[0, -1:], np.float32)           # [1, D]

# head weights: [D, V] (tied embeddings -> embed.T)
head = np.asarray(params.get("lm_head", params["embed"].T), np.float32)

# plaintext logits
logits_plain = h_last @ head

# MPC logits: one session matmul, rectangular [1, D] x [D, V] end to end
sess = connect(MPCSpec(s=2, t=2, z=2))
logits_mpc = np.asarray(sess.matmul(h_last, head, key=jax.random.PRNGKey(2)))

assert logits_mpc.shape == logits_plain.shape == (1, cfg.vocab)
err = np.abs(logits_mpc - logits_plain).max()
print(f"all {cfg.vocab} logits via AGE-CMPC ([1,{cfg.d_model}]x"
      f"[{cfg.d_model},{cfg.vocab}] in {sess.stats['blocks']} coded blocks): "
      f"max |Δ| = {err:.4f}")
assert err < 0.1
top_mpc = int(logits_mpc[0].argmax())
top_plain = int(logits_plain[0].argmax())
assert top_mpc == top_plain, (top_mpc, top_plain)
print(f"greedy next token matches plaintext: {top_mpc}")
print("private inference OK — workers saw only secret shares")

"""Byzantine-robust serving demo (DESIGN.md §9): give the spec an
adversary budget, let a seeded fault injector tamper with worker shares
every round, and watch the session decode the exact product anyway —
localizing the liars by their failed MACs, evicting them like crashed
devices, and refusing (loudly) when the corruption exceeds the budget.

    PYTHONPATH=src python examples/byzantine_demo.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.mpc import FaultInjector, MPCSpec, connect  # noqa: E402

# ---- 1. a spec with an adversary budget ---------------------------------
# a=2 raises the decode quorum from t²+z = 6 to t²+z+2a = 10: the 2a
# extra MAC-checked shares are what lets the master *localize* up to two
# liars per round instead of merely failing
spec = MPCSpec(s=2, t=2, z=2, m=8, adversaries=2)
print(f"spec: {spec.scheme} s={spec.s} t={spec.t} z={spec.z} a=2 -> "
      f"N={spec.n_workers}, quorum {spec.recovery_threshold} -> "
      f"{spec.verified_threshold}")

rng = np.random.default_rng(0)
p = spec.field.p
a = rng.integers(0, p, (16, 16))
b = rng.integers(0, p, (16, 16))
want = np.array((a.astype(object) @ b.astype(object)) % p, np.int64)

# ---- 2. workers 3 and 9 lie every round ---------------------------------
injector = FaultInjector(
    seed=7, schedule={r: [(3, "tamper"), (9, "flip")] for r in range(64)})
sess = connect(spec, backend="local", injector=injector)
y = np.asarray(sess.matmul(a, b, encoded=True))
assert np.array_equal(y, want), "corrupted serving diverged"
print(f"served exactly under {len(injector.log)} injected corruptions: "
      f"{sess.stats['corrections']} shares corrected, liars "
      f"{sorted(sess._dead)} evicted "
      f"({sess.stats['evicted_devices']} devices)")

# ---- 3. evicted liars stay out; serving continues exactly ---------------
y2 = np.asarray(sess.matmul(a, b, encoded=True))
assert np.array_equal(y2, want), "post-eviction serving diverged"
print(f"post-eviction round exact; evicted devices still "
      f"{sess.stats['evicted_devices']}")

# ---- 4. beyond the budget the decode refuses, it never lies -------------
flood = FaultInjector(
    seed=11, schedule={0: [(1, "tamper"), (5, "tamper"), (11, "tamper")]})
angry = connect(spec, backend="local", injector=flood)
try:
    angry.matmul(a, b, encoded=True)
    raise SystemExit("over-budget corruption was not detected")
except RuntimeError as e:
    print(f"three liars vs budget two -> refused: {e}")

print("byzantine demo OK")

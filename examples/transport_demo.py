"""Out-of-process transport demo (DESIGN.md §13): run the N workers of
a plan behind the framed socket transport, check the remote decode is
bit-identical to the in-process oracle, kill a worker mid-flush and
watch the flush degrade into the elastic replan path instead of
hanging, then A/B the pipelined driver against the phase-barriered one
over a simulated 10 ms wire.

    PYTHONPATH=src python examples/transport_demo.py
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.mpc import MPCSpec, connect  # noqa: E402
from repro.mpc.protocol import AGECMPCProtocol  # noqa: E402

# ---- 1. loopback remote workers are bit-identical to local --------------
spec = MPCSpec(s=2, t=2, z=1)
p = spec.field.p
print(f"spec: {spec.scheme} s={spec.s} t={spec.t} z={spec.z} -> "
      f"N={spec.n_workers} remote workers")

rng = np.random.default_rng(0)
a = rng.integers(0, p, (12, 12))
b = rng.integers(0, p, (12, 12))
want = np.array((a.astype(object) @ b.astype(object)) % p, np.int64)

loc = connect(spec)
rem = connect(spec, backend="remote")  # spawn="thread" loopback workers
y_loc = np.asarray(loc.matmul(a, b, encoded=True, m=6))
y_rem = np.asarray(rem.matmul(a, b, encoded=True, m=6))
assert np.array_equal(y_rem, y_loc) and np.array_equal(y_rem, want)
print("remote decode bit-identical to the in-process oracle")

# ---- 2. a worker dies mid-flush: replan, not hang -----------------------
# phase-2 death (the G contribution never leaves) forces the elastic
# path: fail_devices -> retune/replan -> re-dispatch, still exact
proto = AGECMPCProtocol.from_spec(spec, m=6)
rem.backend.chaos(proto, 2, die_block=0, die_after="shares")
y = np.asarray(rem.matmul(a, b, encoded=True, m=6))
assert np.array_equal(y, want), "post-death serving diverged"
st = rem.backend.stats
print(f"worker 2 killed mid-flush -> phase_losses={st['phase_losses']}, "
      f"redispatches={st['redispatches']}, result exact")
rem.backend.close()

# ---- 3. pipelined vs phase-barriered over a simulated 10 ms wire --------
m, blocks = 32, 6
ops = [(rng.integers(0, p, (m, m)), rng.integers(0, p, (m, m)))
       for _ in range(blocks)]
wants = [np.array((x.astype(object) @ y.astype(object)) % p, np.int64)
         for x, y in ops]


def flush_once(sess):
    for x, y in ops:
        sess.submit(x, y, encoded=True, m=m)
    t0 = time.perf_counter()
    outs = sess.flush()
    vals = [np.asarray(outs[rid]) for rid in sorted(outs)]
    dt = time.perf_counter() - t0
    for v, w in zip(vals, wants, strict=True):
        assert np.array_equal(v, w)
    return dt


results = {}
for label, pipelined in (("pipelined", True), ("barriered", False)):
    sess = connect(spec, backend="remote", pipelined=pipelined,
                   delay_s=0.010)
    flush_once(sess)  # warmup: compile + spawn
    results[label] = min(flush_once(sess) for _ in range(2))
    sess.backend.close()

ratio = results["barriered"] / results["pipelined"]
print(f"{blocks} blocks over a 10 ms wire: "
      f"pipelined {results['pipelined'] * 1e3:.0f} ms vs "
      f"barriered {results['barriered'] * 1e3:.0f} ms "
      f"({ratio:.2f}x from overlap)")

print("transport demo OK")

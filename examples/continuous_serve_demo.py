"""Continuous serving demo (DESIGN.md §10): mixed-length prompts arriving
over time, admitted into in-flight decode over a paged KV pool.

Three things to watch:

* requests join *between* decode steps — nobody waits for the batch to
  drain (``admitted_inflight`` in the scheduler stats);
* the paged pool only ever holds what admitted requests actually use —
  the static slab the seed engine would allocate for the same lane count
  is strictly larger (``peak_blocks`` vs ``static_blocks``);
* every request's tokens are bit-identical to the seed one-shot greedy
  loop run on its own.

    PYTHONPATH=src python examples/continuous_serve_demo.py
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.models.api import get_model  # noqa: E402
from repro.serve import Engine  # noqa: E402

cfg = reduced(get_config("llama3.2-1b"))
model = get_model(cfg)
params = model.init_params(cfg, jax.random.PRNGKey(0))
engine = Engine(cfg, params, block_size=4)

MAX_LEN = 32
# 13 usable blocks — well under the 3-lane × 8-block worst case, so the
# demo actually exercises recycling and admission back-pressure
sched = engine.make_scheduler(lanes=3, n_blocks=14, max_len=MAX_LEN)

# a bursty arrival pattern: (arrive_at_step, prompt_len, max_new)
ARRIVALS = [(0, 26, 6), (0, 4, 10), (1, 7, 4), (3, 5, 8), (5, 12, 5),
            (6, 3, 6)]


def prompt_for(i, t):
    return jax.random.randint(jax.random.PRNGKey(10 + i), (1, t), 0,
                              cfg.vocab)


rids, queued, step = {}, list(enumerate(ARRIVALS)), 0
while True:
    while queued and queued[0][1][0] <= step:
        i, (_, t, mn) = queued.pop(0)
        rids[sched.submit(prompt_for(i, t), mn)] = (i, t, mn)
        print(f"step {step:2d}: request {i} arrives (len={t}, max_new={mn}; "
              f"{sched.active()} in flight, {sched.alloc.used_blocks()} "
              f"blocks used)")
    more = sched.step()
    step += 1
    if not more and not queued:
        break

done = sched.finished
for rid, (i, t, mn) in sorted(rids.items()):
    seed = np.asarray(engine._generate_legacy(prompt_for(i, t), mn))[0]
    assert np.array_equal(done[rid], seed), f"request {i} diverged"

static_blocks = sched.lanes * sched.alloc.blocks_for(MAX_LEN)
peak = sched.alloc.stats["peak_used"]
assert peak < static_blocks, "paging should beat worst-case preallocation"
assert sched.stats["admitted_inflight"] >= 1
assert sched.alloc.stats["recycled"] >= 1      # retired blocks reused
print(f"served {len(rids)} mixed-length requests in {sched.stats['steps']} "
      f"decode steps over {sched.lanes} lanes")
print(f"paged footprint: peak {peak} blocks vs static worst-case "
      f"{static_blocks}; scheduler stats {sched.stats}")
print("continuous serving OK: all requests bit-identical to the seed loop")

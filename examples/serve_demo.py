"""Batched serving demo: prefill + greedy decode across model families,
plus the batched MPC request engine (one vmapped program per plan group).

    PYTHONPATH=src python examples/serve_demo.py
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.models.api import get_model  # noqa: E402
from repro.mpc.engine import MPCEngine  # noqa: E402
from repro.serve.engine import Engine  # noqa: E402

for arch in ("llama3.2-1b", "rwkv6-1.6b"):
    cfg = reduced(get_config(arch))
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    out = engine.generate(prompt, 8)
    print(f"{arch:14s} -> {out.shape} sample {out[0].tolist()}")
    assert int(out.max()) < cfg.vocab
print("serving OK")

# ---- MPC request serving: group, vmap, per-request dropout ---------------
mpc = MPCEngine(max_batch=16)
rng = np.random.default_rng(0)
expected = {}
for i in range(8):
    # two plan groups (different m): one vmapped front program each
    prm = dict(s=2, t=2, z=2, m=8 if i % 2 == 0 else 16)
    from repro.mpc import AGECMPCProtocol

    proto = AGECMPCProtocol(**prm)
    p, m = proto.field.p, prm["m"]
    a = rng.integers(0, p, (m, m))
    b = rng.integers(0, p, (m, m))
    surv = None
    if i >= 4:  # half the requests straggle
        surv = np.ones(proto.n_workers, bool)
        surv[rng.choice(proto.n_workers,
                        proto.n_workers - proto.recovery_threshold,
                        replace=False)] = False
    rid = mpc.submit(a, b, key=jax.random.PRNGKey(i), survivors=surv, **prm)
    expected[rid] = np.array(
        (a.astype(object).T @ b.astype(object)) % p, np.int64)
results = mpc.flush()
assert all(np.array_equal(np.asarray(results[r]), expected[r])
           for r in expected)
print(f"mpc serving OK: {len(results)} requests, stats {mpc.stats}")

"""Batched serving demo: prefill + greedy decode across model families,
plus MPC request serving through the unified session API — the batched
backend turns a whole flush into the fewest vmapped program dispatches.

    PYTHONPATH=src python examples/serve_demo.py
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.models.api import get_model  # noqa: E402
from repro.mpc import MPCSpec, connect  # noqa: E402
from repro.serve.engine import Engine  # noqa: E402

for arch in ("llama3.2-1b", "rwkv6-1.6b"):
    cfg = reduced(get_config(arch))
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    out = engine.generate(prompt, 8)
    print(f"{arch:14s} -> {out.shape} sample {out[0].tolist()}")
    assert int(out.max()) < cfg.vocab
print("serving OK")

# ---- MPC request serving: submit/flush on the batched backend ------------
spec = MPCSpec(s=2, t=2, z=2)
sess = connect(spec, backend="batched", max_batch=16)
rng = np.random.default_rng(0)
expected = {}
for i in range(8):
    # two block sizes: requests group by plan, one vmapped front each
    m = 8 if i % 2 == 0 else 16
    p = spec.field.p
    a = rng.integers(0, p, (m, m))
    b = rng.integers(0, p, (m, m))
    surv = None
    if i >= 4:  # half the requests straggle down to the decode threshold
        surv = np.ones(spec.n_workers, bool)
        surv[rng.choice(spec.n_workers,
                        spec.n_workers - spec.recovery_threshold,
                        replace=False)] = False
    rid = sess.submit(a, b, key=jax.random.PRNGKey(i), survivors=surv,
                      encoded=True, m=m)
    expected[rid] = np.array(
        (a.astype(object) @ b.astype(object)) % p, np.int64)
results = sess.flush()
assert all(np.array_equal(np.asarray(results[r]), expected[r])
           for r in expected)
print(f"mpc serving OK: {len(results)} requests in one flush, "
      f"engine stats {sess.backend.engine.stats}")

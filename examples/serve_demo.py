"""Batched serving demo: prefill + greedy decode across families.

    PYTHONPATH=src python examples/serve_demo.py
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.models.api import get_model  # noqa: E402
from repro.serve.engine import Engine  # noqa: E402

for arch in ("llama3.2-1b", "rwkv6-1.6b"):
    cfg = reduced(get_config(arch))
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    out = engine.generate(prompt, 8)
    print(f"{arch:14s} -> {out.shape} sample {out[0].tolist()}")
    assert int(out.max()) < cfg.vocab
print("serving OK")

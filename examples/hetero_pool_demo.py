"""Heterogeneous worker-pool demo (DESIGN.md §8): tune over a 2-class
edge roster, watch the placement land heavy shares on high-capacity
devices, serve exactly through a session, and re-tune on device failures
with the *surviving* capacity vector.

    PYTHONPATH=src python examples/hetero_pool_demo.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.mpc import CostModel, WorkerClass, WorkerPool, connect, tune  # noqa: E402
from repro.mpc.workers import modeled_makespan  # noqa: E402

# ---- 1. a skewed 2-class pool: 12 phones + 8 gateways -------------------
PHONE = WorkerClass("phone", compute=10.0, storage=8.0, link=25.0)
GATEWAY = WorkerClass("gateway", compute=1.0, storage=1.0, link=1.0)
pool = WorkerPool.of((PHONE, 12), (GATEWAY, 8))
print(f"pool: {pool.describe()} ({len(pool)} devices)")

# weights calibrated from the measured trajectory when present (ROADMAP
# "Measured cost models"); the paper's equal weights otherwise
cost = CostModel.from_bench("BENCH_PROTOCOL.json")
z, shape = 2, (32, 64, 16)
res = tune(pool=pool, z=z, shape=shape, cost=cost)
spec = res.spec
print(f"tuned: {spec.scheme} s={spec.s} t={spec.t} λ={spec.lam} "
      f"N={spec.n_workers} m={spec.m}")
print(f"placement (device ids per worker slot): {spec.placement}")
names = [pool[d].name for d in spec.placement]
print(f"  -> classes: {names[:8]}{'...' if len(names) > 8 else ''}")
assert all(pool[d] is GATEWAY
           for d in spec.placement[: spec.recovery_threshold]), \
    "decode-quorum slots must land on high-capacity devices"

# capacity-aware placement vs capacity-oblivious identity, per-slot model
placed = modeled_makespan(spec.m, spec.s, spec.t, z, spec.n_workers, cost,
                          pool, spec.effective_placement)
oblivious = modeled_makespan(spec.m, spec.s, spec.t, z, spec.n_workers,
                             cost, pool, tuple(range(spec.n_workers)))
print(f"modeled block makespan: placed {placed:.3e} vs oblivious "
      f"{oblivious:.3e} ({oblivious / placed:.1f}x win)")
assert placed < oblivious

# ---- 2. serve through the session: floats in, floats out ----------------
sess = res.connect()
rng = np.random.default_rng(0)
a = rng.standard_normal(shape[:2])
b = rng.standard_normal(shape[1:])
y = np.asarray(sess.matmul(a, b))
err = float(np.abs(y - a @ b).max())
print(f"session matmul {a.shape} x {b.shape}: max |err| = {err:.4f}")
assert err < 0.1

# ---- 3. device failures: ids are roster DEVICE ids ----------------------
sess.fail([spec.placement[0], 0])   # a placed gateway + an unplaced phone
y2 = np.asarray(sess.matmul(a, b))
assert float(np.abs(y2 - a @ b).max()) < 0.1
print("after device failures: still exact (coded phase-3 tolerance)")

# ---- 4. elastic spares + surviving-capacity re-tune ---------------------
from repro.mpc.elastic import ElasticPool  # noqa: E402

ep = ElasticPool.from_spec(spec, spares=3)
spare_classes = [pool[d].name for d in ep.device_map[spec.n_workers:]]
print(f"spare inventory (high-capacity first): {spare_classes}")
ep.fail_devices(list(spec.placement[:3]))   # lose 3 placed gateways
surv = ep.surviving_devices()
new = ep.retune(cost)
print(f"after losing 3 gateways: {len(surv)} provisioned devices survive "
      f"({[pool[d].name for d in surv].count('gateway')} gateways); "
      f"re-tuned to s={new.s} t={new.t} N={new.n_workers} "
      f"placed on {[pool[d].name for d in new.spec.placement][:5]}... "
      f"(same roster ids — failure routing stays valid)")
print("hetero pool demo OK")

"""Autotune demo: the paper's optimization layer, end to end.

Given a worker budget, a privacy bound and a workload shape, the tuner
searches the generalized code family (AGE over every feasible (s, t, λ),
Entangled, PolyDot) under the closed-form worker counts, ranks candidates
by the weighted Cor. 8–10 overhead objective, co-optimizes the coded tile
side with the partition — and the winning frozen spec drops straight into
``connect``.  Attrition re-tunes before it re-plans.

    PYTHONPATH=src python examples/autotune_demo.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.mpc import CostModel, MPCSpec, connect  # noqa: E402
from repro.mpc.autotune import tune  # noqa: E402

# ---- 1. tune: budget N=24 edge devices, z=2 colluders, a [32,64]x[64,16]
#         projection served in batches of 4
budget, z, shape, batch = 24, 2, (32, 64, 16), 4
res = tune(budget, z, shape, batch=batch)
print(f"workload [r,k]x[k,c]={shape} batch={batch}, budget N<={budget}, z={z}")
print("top candidates (scheme s t λ -> N, tile m, blocks, score):")
for c in res.candidates[:5]:
    print(f"  {c.scheme:>9} s={c.s} t={c.t} λ={c.lam} -> N={c.n_workers:2d} "
          f"m={c.m:3d} blocks={c.n_blocks:2d} score={c.score:.3e}")
spec = res.spec
print(f"tuned spec: {spec.scheme} (s={spec.s}, t={spec.t}, λ={spec.lam}), "
      f"N={spec.n_workers}, tile m={spec.m}; predicted per-block "
      f"ξ={res.predicted.computation:.3e} σ={res.predicted.storage:.3e} "
      f"ζ={res.predicted.communication:.3e}")

# ---- 2. connect + matmul round-trip: floats in, floats out
sess = res.connect()
rng = np.random.default_rng(0)
a = rng.standard_normal((batch, shape[0], shape[1]))
b = rng.standard_normal((shape[1], shape[2]))
y = np.asarray(sess.matmul(a, b))
err = float(np.abs(y - a @ b).max())
print(f"tune -> connect -> matmul: batched {a.shape} x {b.shape} -> "
      f"{y.shape}, max |err| = {err:.4f}")
assert err < 0.1, "tuned session output diverged"

# ---- 3. the weights arbitrate the paper's s/t trade-off (Fig. 2/3)
for label, cm in [("communication-bound edge", CostModel(0.0, 0.0, 1.0)),
                  ("computation-bound edge", CostModel(1.0, 0.0, 0.0))]:
    r2 = tune(60, z, (64, 64, 64), cost=cm)
    b2 = r2.best
    print(f"{label}: picks {b2.scheme} s={b2.s} t={b2.t} "
          f"(N={b2.n_workers}, st²={b2.s * b2.t * b2.t})")

# ---- 4. attrition: the batched backend re-tunes before it re-plans
spec8 = MPCSpec(s=2, t=2, z=2, m=8)
sess8 = connect(spec8, backend="batched", spares=1)
p = spec8.field.p
ae = rng.integers(0, p, (8, 8))
be_ = rng.integers(0, p, (8, 8))
sess8.fail(list(range(spec8.n_workers - 7)))       # 8 of 18 pool survive
y8 = np.asarray(sess8.matmul(ae, be_, encoded=True))
want = np.array((ae.astype(object) @ be_.astype(object)) % p, np.int64)
assert np.array_equal(y8, want), "re-tuned decode diverged"
stats = sess8.backend.engine.stats
print(f"attrition below N: served exactly under a re-tuned spec "
      f"(engine stats: replans={stats['replans']}, "
      f"retunes={stats['retunes']})")
print("autotune demo OK")
